"""Bench-trend gate: fail CI only on *regressions* against committed
baselines (the bench-side sibling of scripts/check_regressions.py).

Each BENCH_*.json artifact a bench run emits (bench_serving,
bench_serving --fleet, bench_kernels) is compared against the same-name
baseline committed under benchmarks/baselines/. A per-bench extractor
pulls the gated metrics and their direction — span throughput must not
drop, TTFT/latency must not rise, drained/token-identity booleans must
not flip false, rejected counts must not appear — and the gate fails
only when a metric moves the *wrong* way by more than the relative
tolerance. Improvements never fail (ratchet style: re-record them with
--update when you want the tighter floor committed).

Modeled metrics (span_tok_s, ttft_ms — deterministic fleet/device
clocks) are gated at the base tolerance; wall-clock kernel timings
(bench_kernels us/call on shared CI runners) are inherently noisy, so
their extractor widens the tolerance (scale 8: at the default 25% they
fail only past ~3x) rather than flaking the gate.

  python scripts/check_bench_trend.py BENCH_serving_1dev.json \\
      BENCH_fleet.json                              # gate (CI)
  python scripts/check_bench_trend.py --update B.json   # re-record
  python scripts/check_bench_trend.py --self-test B.json

--self-test proves the gate has teeth: each file must pass against
itself and must FAIL once a synthetic regression is injected into
every gated metric — a no-op gate (empty extractor, inverted
direction) exits nonzero here, so CI runs it next to the real check.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _ratchet import dump_json, load_json  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE_DIR = os.path.join(REPO, "benchmarks", "baselines")

# metric direction: the value must not move the other way
HIGHER, LOWER = "higher", "lower"

# wall-clock timings on shared CI runners vary far more than modeled
# (deterministic) metrics: widen their tolerance by this factor
NOISY = 8.0


# ------------------------------------------------- per-bench extract ----


def _serving_metrics(p: dict) -> dict:
    """bench_serving mesh/spec legs: span throughput and latency per
    (system, dp, tp) row; token identity across tp must not flip."""
    m = {}
    for r in p.get("results", []):
        tag = f"{r['system']}_dp{r['dp']}_tp{r['tp']}"
        m[f"{tag}_span_tok_s"] = (r["span_tok_s"], HIGHER, 1.0)
        m[f"{tag}_tok_s"] = (r["tok_s"], HIGHER, 1.0)
        m[f"{tag}_ttft_ms"] = (r["ttft_ms"], LOWER, 1.0)
        m[f"{tag}_p99_ms"] = (r["p99_ms"], LOWER, 1.0)
        if "tokens_identical" in r:
            val = int(bool(r["tokens_identical"]))
            m[f"{tag}_tokens_identical"] = (val, HIGHER, 0.0)
    return m


def _moe_sparse_metrics(p: dict) -> dict:
    """The intra-expert pricing leg: the two-level cold-byte win and
    the token identity that proves it is pricing-only."""
    m = {
        "cold_bytes_ratio": (p["cold_bytes_ratio"], LOWER, 1.0),
        "tokens_identical": (int(bool(p["tokens_identical"])), HIGHER, 0.0),
    }
    leg = p.get("legs", {}).get("intra_expert", {})
    if "tok_s" in leg:
        m["intra_expert_tok_s"] = (leg["tok_s"], HIGHER, 1.0)
    return m


def _quant_metrics(p: dict) -> dict:
    """bench_serving --storage-dtype: per (family, dtype, dp, tp) cell
    the modeled throughput must not drop and cold-store bytes/token
    must not rise; bundle_bytes is pure §4.4 accounting (exact);
    fp16/quantized byte ratios and token agreement must not sag, and
    the Table-7 quant-error proxies must not grow."""
    m = {}
    for r in p.get("results", []):
        tag = f"{r['family']}_{r['storage_dtype']}_dp{r['dp']}_tp{r['tp']}"
        m[f"{tag}_tok_s"] = (r["tok_s"], HIGHER, 1.0)
        m[f"{tag}_cold_bytes_per_tok"] = (r["cold_bytes_per_tok"], LOWER, 1.0)
        m[f"{tag}_bundle_bytes"] = (r["bundle_bytes"], LOWER, 0.0)
        m[f"{tag}_token_agreement"] = (r["token_agreement"], HIGHER, 1.0)
    for name, v in p.get("ratios", {}).items():
        m[f"ratio_{name}"] = (v, HIGHER, 1.0)
    for fam, errs in p.get("quant_error", {}).items():
        for scheme, v in errs.items():
            m[f"quant_error_{fam}_{scheme}"] = (v, LOWER, 1.0)
    return m


def _fleet_metrics(p: dict) -> dict:
    """bench_serving --fleet: the saturation curve must not sag, the
    TTFT split must not rise, nothing may be rejected or undrained —
    in the sweep or in the loss/rejoin + draining scenarios."""
    m = {}
    for r in p.get("results", []):
        tag = f"fleet{r['fleet']}_r{r['rate']:g}"
        m[f"{tag}_span_tok_s"] = (r["span_tok_s"], HIGHER, 1.0)
        m[f"{tag}_ttft_miss_p50_ms"] = (r["ttft_miss_ms"]["p50"], LOWER, 1.0)
        m[f"{tag}_n_rejected"] = (r["n_rejected"], LOWER, 0.0)
        m[f"{tag}_drained"] = (int(bool(r["drained"])), HIGHER, 0.0)
    for name, sc in p.get("scenarios", {}).items():
        m[f"scenario_{name}_drained"] = (int(bool(sc["drained"])), HIGHER, 0.0)
        m[f"scenario_{name}_n_rejected"] = (sc["n_rejected"], LOWER, 0.0)
    return m


def _kernels_metrics(p: dict) -> dict:
    """bench_kernels: structural traffic fractions are deterministic
    (tight); us/call wall timings on CI runners are noisy (NOISY)."""
    m = {}
    for r in p.get("results", []):
        tag = f"{r['leg']}_b{r['batch']}"
        frac = r["weight_traffic_fraction"]
        m[f"{tag}_weight_traffic_fraction"] = (frac, LOWER, 1.0)
        m[f"{tag}_t_xla_cold_s"] = (r["t_xla_cold_s"], LOWER, NOISY)
        m[f"{tag}_t_pallas_cold_s"] = (r["t_pallas_cold_s"], LOWER, NOISY)
    calib = p.get("calibration", {})
    for k in ("dense_flops_per_s", "sparse_flops_per_s"):
        if k in calib:
            m[f"calibrated_{k}"] = (calib[k], HIGHER, NOISY)
    return m


EXTRACTORS = {
    "serving": _serving_metrics,
    "serving_moe_sparse": _moe_sparse_metrics,
    "serving_quant": _quant_metrics,
    "fleet": _fleet_metrics,
    "kernels": _kernels_metrics,
}


def extract(payload: dict, path: str) -> dict:
    bench = payload.get("bench")
    if bench not in EXTRACTORS:
        print(
            f"[check_bench_trend] {path}: unknown bench kind "
            f"{bench!r} (gated kinds: {sorted(EXTRACTORS)})",
            file=sys.stderr,
        )
        sys.exit(2)
    metrics = EXTRACTORS[bench](payload)
    if not metrics:
        print(
            f"[check_bench_trend] {path}: extractor produced no "
            f"metrics — an empty artifact must not pass the gate",
            file=sys.stderr,
        )
        sys.exit(2)
    return metrics


# ------------------------------------------------------- comparison ----


def compare(fresh: dict, base: dict, tol: float) -> tuple:
    """(regressions, notes): a metric regresses when it moves the
    wrong way past tol * its per-metric scale (scale 0 = exact)."""
    problems, notes = [], []
    for name in sorted(base):
        bval, direction, scale = base[name]
        if name not in fresh:
            problems.append(f"{name}: in baseline but missing from the fresh run")
            continue
        fval = fresh[name][0]
        t = tol * scale
        if direction == HIGHER and fval < bval * (1 - t) - 1e-12:
            drop = (1 - fval / bval) * 100 if bval else 100.0
            problems.append(
                f"{name}: {fval} < baseline {bval} "
                f"(-{drop:.1f}%, tol {t * 100:.0f}%)"
            )
        elif direction == LOWER and fval > bval * (1 + t) + 1e-12:
            rise = (fval / bval - 1) * 100 if bval else float("inf")
            problems.append(
                f"{name}: {fval} > baseline {bval} "
                f"(+{rise:.1f}%, tol {t * 100:.0f}%)"
            )
    for name in sorted(set(fresh) - set(base)):
        notes.append(
            f"{name}: new metric, not yet in the baseline — record with --update"
        )
    return problems, notes


def inject_regression(metrics: dict, tol: float) -> dict:
    """Worsen every gated metric past its tolerance — the self-test's
    synthetic regression."""
    bad = {}
    for name, (val, direction, scale) in metrics.items():
        t = max(tol * scale, 0.05)
        if direction == HIGHER:
            bad[name] = (val * (1 - 2 * t) - 1.0, direction, scale)
        else:
            bad[name] = (val * (1 + 2 * t) + 1.0, direction, scale)
    return bad


def _self_test(name: str, fresh: dict, tol: float) -> int:
    ok_probs, _ = compare(fresh, fresh, tol)
    bad_probs, _ = compare(inject_regression(fresh, tol), fresh, tol)
    if ok_probs:
        print(
            f"[check_bench_trend] SELF-TEST {name}: clean "
            f"self-comparison reported regressions: {ok_probs}"
        )
        return 1
    if len(bad_probs) != len(fresh):
        print(
            f"[check_bench_trend] SELF-TEST {name}: injected "
            f"regression into {len(fresh)} metrics but only "
            f"{len(bad_probs)} tripped the gate"
        )
        return 1
    print(
        f"[check_bench_trend] SELF-TEST {name}: OK "
        f"({len(fresh)} metrics; clean passes, injected "
        f"regression trips all)"
    )
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", nargs="+", help="BENCH_*.json artifacts from this run")
    ap.add_argument("--baseline-dir", default=DEFAULT_BASELINE_DIR)
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="relative tolerance for modeled metrics (noisy wall-time "
        "metrics scale it up, exact booleans/counters scale it to 0)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="commit the fresh artifacts as the new baselines instead of gating",
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="prove the gate trips: each artifact must pass vs itself "
        "and fail once a synthetic regression is injected",
    )
    args = ap.parse_args()

    rc = 0
    for path in args.fresh:
        if not os.path.exists(path):
            print(
                f"[check_bench_trend] {path}: fresh artifact missing "
                f"— the bench leg did not produce it",
                file=sys.stderr,
            )
            rc = 1
            continue
        payload = load_json(path)
        fresh = extract(payload, path)
        name = os.path.basename(path)
        bpath = os.path.join(args.baseline_dir, name)

        if args.self_test:
            rc = _self_test(name, fresh, args.tolerance) or rc
            continue

        if args.update:
            os.makedirs(args.baseline_dir, exist_ok=True)
            dump_json(bpath, payload)
            print(
                f"[check_bench_trend] baseline <- {name} ({len(fresh)} gated metrics)"
            )
            continue

        if not os.path.exists(bpath):
            print(
                f"[check_bench_trend] {name}: no committed baseline "
                f"at {bpath} — record one with --update"
            )
            rc = 1
            continue
        base = extract(load_json(bpath), bpath)
        problems, notes = compare(fresh, base, args.tolerance)
        print(
            f"[check_bench_trend] {name}: {len(base)} baseline "
            f"metrics, {len(problems)} regression(s)"
        )
        for msg in problems:
            print(f"  REGRESSION {msg}")
        for msg in notes:
            print(f"  note: {msg}")
        if problems:
            rc = 1
    if rc == 0 and not args.self_test:
        print("[check_bench_trend] OK: no regressions")
    return rc


if __name__ == "__main__":
    sys.exit(main())
